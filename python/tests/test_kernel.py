"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes / head configs / lengths; fixed-seed cases pin the
exact tolerances. This is the CORE correctness signal for the artifacts the
rust runtime executes.
"""

import jax.lax as lax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention
from compile.kernels.window_attention import window_attention
from compile.kernels.lava_score import lava_score

SETTINGS = dict(max_examples=12, deadline=None)


def make_qkv(rng, h, hk, n, dh, scale=1.0):
    q = jnp.array(rng.normal(size=(h, n, dh)) * scale, jnp.float32)
    k = jnp.array(rng.normal(size=(hk, n, dh)) * scale, jnp.float32)
    v = jnp.array(rng.normal(size=(hk, n, dh)) * scale, jnp.float32)
    return q, k, v


# ---------------------------------------------------------------- flash

@pytest.mark.parametrize("n,length", [(128, 128), (128, 100), (256, 161), (512, 512)])
def test_flash_matches_ref(n, length):
    rng = np.random.default_rng(n + length)
    q, k, v = make_qkv(rng, 8, 4, n, 16)
    o, acc = flash_attention(q, k, v, jnp.array([length], jnp.int32))
    o_ref, acc_ref = ref.causal_attention_ref(q, k, v, length)
    np.testing.assert_allclose(o[:, :length], o_ref[:, :length], atol=2e-5)
    np.testing.assert_allclose(acc, acc_ref, atol=2e-4)


def test_flash_acc_is_probability_mass():
    """Column masses over valid tokens sum to the number of valid rows."""
    rng = np.random.default_rng(7)
    n, length = 256, 200
    q, k, v = make_qkv(rng, 8, 4, n, 16)
    _, acc = flash_attention(q, k, v, jnp.array([length], jnp.int32))
    np.testing.assert_allclose(
        jnp.sum(acc, axis=-1), jnp.full(8, length, jnp.float32), rtol=1e-4
    )
    # no attention mass beyond `length`
    assert float(jnp.abs(acc[:, length:]).max()) < 1e-6


@settings(**SETTINGS)
@given(
    h_groups=st.sampled_from([(8, 4), (8, 8), (4, 2), (8, 2)]),
    n=st.sampled_from([64, 128, 256]),
    dh=st.sampled_from([8, 16, 32]),
    frac=st.floats(0.3, 1.0),
    seed=st.integers(0, 2**16),
)
def test_flash_hypothesis(h_groups, n, dh, frac, seed):
    h, hk = h_groups
    length = max(33, int(n * frac))
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, h, hk, n, dh)
    o, acc = flash_attention(q, k, v, jnp.array([length], jnp.int32))
    o_ref, acc_ref = ref.causal_attention_ref(q, k, v, length)
    np.testing.assert_allclose(o[:, :length], o_ref[:, :length], atol=5e-5)
    np.testing.assert_allclose(acc, acc_ref, atol=5e-4)


# ---------------------------------------------------------------- window

@pytest.mark.parametrize("n,length,w", [(128, 128, 32), (256, 200, 32), (256, 64, 16)])
def test_window_matches_ref(n, length, w):
    rng = np.random.default_rng(length)
    q, k, _ = make_qkv(rng, 8, 4, n, 16)
    qw = lax.dynamic_slice(q, (0, length - w, 0), (8, w, 16))
    got = window_attention(qw, k, jnp.array([length], jnp.int32), w)
    want = ref.window_attention_ref(qw, k, length, w)
    np.testing.assert_allclose(got, want, atol=2e-6)


def test_window_rows_are_distributions():
    rng = np.random.default_rng(1)
    n, length, w = 256, 180, 32
    q, k, _ = make_qkv(rng, 8, 4, n, 16)
    qw = lax.dynamic_slice(q, (0, length - w, 0), (8, w, 16))
    a = window_attention(qw, k, jnp.array([length], jnp.int32), w)
    np.testing.assert_allclose(jnp.sum(a, axis=-1), jnp.ones((8, w)), rtol=1e-5)
    assert float(jnp.abs(a[..., length:]).max()) == 0.0
    # causality: row r may not attend past position length - w + r
    for r in (0, 15, 31):
        assert float(jnp.abs(a[:, r, length - w + r + 1:]).max()) == 0.0


@settings(**SETTINGS)
@given(
    n=st.sampled_from([64, 128, 256]),
    w=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
    frac=st.floats(0.5, 1.0),
)
def test_window_hypothesis(n, w, seed, frac):
    length = max(w + 1, int(n * frac))
    rng = np.random.default_rng(seed)
    q, k, _ = make_qkv(rng, 8, 4, n, 16)
    qw = lax.dynamic_slice(q, (0, length - w, 0), (8, w, 16))
    got = window_attention(qw, k, jnp.array([length], jnp.int32), w)
    want = ref.window_attention_ref(qw, k, length, w)
    np.testing.assert_allclose(got, want, atol=5e-6)


# ---------------------------------------------------------------- lava score

@pytest.mark.parametrize("group,pool", [(2, 7), (1, 7), (4, 3), (2, 1)])
def test_lava_score_matches_ref(group, pool):
    rng = np.random.default_rng(group * 10 + pool)
    hk, n, dh, w = 4, 256, 16, 32
    h = hk * group
    length = 211
    q, k, v = make_qkv(rng, h, hk, n, dh)
    qw = lax.dynamic_slice(q, (0, length - w, 0), (h, w, dh))
    win = window_attention(qw, k, jnp.array([length], jnp.int32), w)
    got = lava_score(win, v, jnp.array([length], jnp.int32), group, pool)
    want = ref.lava_score_ref(win, v, length, group, pool)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_lava_score_scales_with_value_norm():
    """Doubling V doubles the score (Definition 1: s ∝ max ||V||_1)."""
    rng = np.random.default_rng(3)
    hk, n, dh, w, g = 4, 128, 16, 32, 2
    win = jnp.array(rng.uniform(size=(hk * g, w, n)), jnp.float32)
    v = jnp.array(rng.normal(size=(hk, n, dh)), jnp.float32)
    length = jnp.array([100], jnp.int32)
    s1 = lava_score(win, v, length, g, 7)
    s2 = lava_score(win, 2.0 * v, length, g, 7)
    np.testing.assert_allclose(s2, 2.0 * s1, rtol=1e-5)


def test_lava_score_group_max_dominates_heads():
    """Group score >= mean window attention of each member head x vbar."""
    rng = np.random.default_rng(4)
    hk, n, dh, w, g = 2, 128, 16, 32, 4
    win = jnp.array(rng.uniform(size=(hk * g, w, n)), jnp.float32)
    v = jnp.array(rng.normal(size=(hk, n, dh)), jnp.float32)
    length = 96
    s = np.asarray(lava_score(win, v, jnp.array([length], jnp.int32), g, 1))
    vnorm = jnp.sum(jnp.abs(v), axis=-1)
    vbar = np.asarray(jnp.max(vnorm[:, :length], axis=-1))
    a_mean = np.asarray(jnp.mean(win, axis=1))
    for kvh in range(hk):
        for member in range(g):
            per_head = a_mean[kvh * g + member, :length] * vbar[kvh]
            assert (s[kvh, :length] + 1e-6 >= per_head).all()


@settings(**SETTINGS)
@given(
    hk=st.sampled_from([2, 4]),
    group=st.sampled_from([1, 2, 4]),
    n=st.sampled_from([64, 128]),
    pool=st.sampled_from([1, 3, 7]),
    seed=st.integers(0, 2**16),
)
def test_lava_score_hypothesis(hk, group, n, pool, seed):
    rng = np.random.default_rng(seed)
    w, dh = 16, 8
    h = hk * group
    length = int(rng.integers(w + 1, n + 1))
    q, k, v = make_qkv(rng, h, hk, n, dh)
    qw = lax.dynamic_slice(q, (0, length - w, 0), (h, w, dh))
    win = window_attention(qw, k, jnp.array([length], jnp.int32), w)
    got = lava_score(win, v, jnp.array([length], jnp.int32), group, pool)
    want = ref.lava_score_ref(win, v, length, group, pool)
    np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------- maxpool

@settings(**SETTINGS)
@given(
    n=st.integers(8, 64),
    kernel=st.sampled_from([1, 3, 5, 7]),
    seed=st.integers(0, 2**16),
)
def test_maxpool_properties(n, kernel, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=(3, n)), jnp.float32)
    y = np.asarray(ref.maxpool1d_ref(x, kernel))
    xn = np.asarray(x)
    half = kernel // 2
    assert (y >= xn - 1e-7).all()
    for i in range(n):
        lo, hi = max(0, i - half), min(n, i + half + 1)
        np.testing.assert_allclose(y[:, i], xn[:, lo:hi].max(axis=1), rtol=1e-6)
