"""Build-path tests: data generators, a tiny training run, and AOT lowering.

These guard the `make artifacts` pipeline itself (the only python that ever
runs); kernel/model numerics live in test_kernel.py / test_model.py.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, train
from compile.config import MODEL, ARTIFACTS
from compile import aot
from compile import model as M


# ---------------------------------------------------------------- data

@pytest.mark.parametrize("gen", data.GENERATORS)
def test_generators_shapes(gen):
    rng = np.random.default_rng(0)
    for seq_len in (64, 160, 512):
        toks, mask = gen(rng, seq_len)
        assert len(toks) == len(mask)
        assert len(toks) <= seq_len + 8
        assert mask.any(), "every sample must supervise something"
        assert (toks >= 0).all() and (toks < MODEL.vocab_size).all()


def test_echo_task_is_copy():
    rng = np.random.default_rng(1)
    toks, mask = data.gen_echo(rng, 66)
    m = (66 - 2) // 2
    assert toks[0] == MODEL.bos_id and toks[m + 1] == MODEL.sep_id
    np.testing.assert_array_equal(toks[1 : m + 1], toks[m + 2 :])
    assert mask[m + 2 :].all() and not mask[: m + 2].any()


def test_copy_task_echo_after_filler():
    rng = np.random.default_rng(1)
    toks, mask = data.gen_copy(rng, 160)
    m = int(mask.sum())
    # supervised suffix equals the payload right after BOS
    np.testing.assert_array_equal(toks[1 : 1 + m], toks[-m:])
    assert toks[len(toks) - m - 1] == MODEL.query_id


def test_motif_supervision_is_sparse():
    rng = np.random.default_rng(2)
    toks, mask = data.gen_motif(rng, 256)
    assert 0 < mask.sum() <= 32, "dense motif supervision blocks training"


def test_needle_answer_is_retrievable():
    rng = np.random.default_rng(2)
    toks, mask = data.gen_needle(rng, 256)
    # the supervised suffix equals the needle value embedded in the body
    val = toks[mask]
    key = toks[np.where(toks == MODEL.query_id)[0][-1] + 1]
    body = list(toks)
    ki = body.index(MODEL.sep_id) + 1
    assert body[ki] == key
    np.testing.assert_array_equal(body[ki + 1 : ki + 1 + len(val)], val)


def test_batch_padding():
    rng = np.random.default_rng(3)
    ids, mask = data.batch(rng, 4, 128)
    assert ids.shape == (4, 128) and mask.shape == (4, 128)
    assert ids.dtype == np.int32
    # PAD-ed tails are never supervised
    assert not (mask & (ids == MODEL.pad_id)).any()


# ---------------------------------------------------------------- train

def test_loss_decreases_quick():
    params, hist = train.train(steps=8, lr=3e-3, seed=7, log_every=100,
                               log=lambda s: None)
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.5  # no blow-up
    assert np.isfinite(hist[-1]["loss"])


def test_save_load_roundtrip(tmp_path):
    params = M.init_params(jax.random.PRNGKey(0))
    path = tmp_path / "w.npz"
    train.save(params, str(path))
    loaded = train.load(str(path))
    np.testing.assert_array_equal(params["tok_emb"], loaded["tok_emb"])
    for li in range(MODEL.n_layers):
        for k in M.LAYER_WEIGHT_NAMES:
            np.testing.assert_array_equal(
                params["layers"][li][k], loaded["layers"][li][k]
            )


# ---------------------------------------------------------------- aot

def test_hlo_text_lowering_smoke(tmp_path):
    """Lower the smallest real entrypoints and sanity-check the HLO text."""
    lw = aot.layer_weight_specs()
    path = tmp_path / "logits.hlo.txt"
    aot.lower_to_file(
        M.logits,
        [aot.sds((1, MODEL.d_model)), aot.sds((MODEL.d_model,)),
         aot.sds((MODEL.d_model, MODEL.vocab_size))],
        str(path),
    )
    text = path.read_text()
    assert "ENTRY" in text and "f32[260]" in text

    path2 = tmp_path / "decode.hlo.txt"
    m = 128
    hk, dh = MODEL.n_kv_heads, MODEL.d_head
    aot.lower_to_file(
        M.layer_decode,
        [aot.sds((1, MODEL.d_model)), aot.sds((hk, m, dh)), aot.sds((hk, m, dh)),
         aot.sds((hk, m)), aot.sds((1,), jnp.int32)]
        + [aot.sds(s) for _, s in lw],
        str(path2),
    )
    assert "ENTRY" in path2.read_text()


def test_manifest_covers_all_weights():
    """Weight specs in the manifest must match the real parameter shapes."""
    params = M.init_params(jax.random.PRNGKey(1))
    for name, shape in aot.layer_weight_specs():
        assert tuple(params["layers"][0][name].shape) == shape


def test_buckets_are_compatible():
    for n in ARTIFACTS.prefill_buckets:
        assert n % 32 == 0 and n >= MODEL.window
    for m in ARTIFACTS.decode_buckets:
        assert m >= ARTIFACTS.prefill_buckets[0]
    # rust names chunked artifacts layer_prefill_chunked_{C}x{N} with C =
    # the prefill bucket a chunk rounds up to, so every lowered C must
    # itself be a prefill bucket or the names can never match
    for c in ARTIFACTS.prefill_chunk_sizes:
        assert c in ARTIFACTS.prefill_buckets
