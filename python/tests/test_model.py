"""L2 model correctness: composed entrypoints vs the plain-jnp reference.

Validates exactly what the rust coordinator relies on:
  * layer_prefill over a padded bucket == unpadded reference (per layer)
  * chained layers + logits == reference next-token logits
  * layer_decode(step N+1 | full cache of N) == prefill of N+1 tokens
  * eviction invariance: decode over a cache with evicted slots == decode
    over the compacted cache (the masking contract the kvcache manager uses)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import MODEL

CFG = MODEL


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(42))


@pytest.fixture(scope="module")
def ids():
    rng = np.random.default_rng(0)
    return jnp.array(rng.integers(0, 256, size=100), jnp.int32)


def lw_args(params, li):
    lw = params["layers"][li]
    return [lw[k] for k in M.LAYER_WEIGHT_NAMES]


def run_prefill_padded(params, ids, bucket):
    """Drive the actual entrypoints the way rust does (padded to bucket)."""
    n = ids.shape[0]
    padded = jnp.concatenate(
        [ids, jnp.full((bucket - n,), CFG.pad_id, jnp.int32)]
    )
    length = jnp.array([n], jnp.int32)
    x = M.embed(padded, params["tok_emb"])
    outs = []
    for li in range(CFG.n_layers):
        x, k, v, win, acc, vnorm = M.layer_prefill(x, length, *lw_args(params, li))
        outs.append(dict(k=k, v=v, win_attn=win, acc_attn=acc, vnorm=vnorm, x=x))
    return outs


def test_prefill_matches_reference(params, ids):
    n = int(ids.shape[0])
    bucket = 128
    got = run_prefill_padded(params, ids, bucket)
    want, ref_logits = M.reference_prefill(params, ids)
    for li in range(CFG.n_layers):
        np.testing.assert_allclose(
            got[li]["k"][:, :n], want[li]["k"], atol=3e-5, rtol=1e-4
        )
        np.testing.assert_allclose(
            got[li]["v"][:, :n], want[li]["v"], atol=3e-5, rtol=1e-4
        )
        np.testing.assert_allclose(
            got[li]["x"][:n], want[li]["x_out"], atol=3e-4, rtol=1e-3
        )
        np.testing.assert_allclose(
            got[li]["win_attn"][:, :, :n], want[li]["win_attn"], atol=3e-5
        )
        np.testing.assert_allclose(
            got[li]["acc_attn"][:, :n], want[li]["acc_attn"], atol=3e-4
        )
        np.testing.assert_allclose(
            got[li]["vnorm"][:, :n], want[li]["vnorm"], atol=3e-5, rtol=1e-4
        )
        # padding region carries no cache content the coordinator would read
        assert float(jnp.abs(got[li]["win_attn"][:, :, n:]).max()) == 0.0


def run_prefill_chunked(params, ids, bucket, chunk, cbucket):
    """Drive layer_prefill_chunked the way rust does: per layer, walk the
    prompt in `chunk`-token steps (each padded to the `cbucket` artifact
    width), scatter the returned K/V into the carry, and accumulate the
    additive win/acc/vnorm panels."""
    n = int(ids.shape[0])
    padded = jnp.concatenate(
        [ids, jnp.full((bucket - n,), CFG.pad_id, jnp.int32)]
    )
    x = M.embed(padded, params["tok_emb"])
    outs = []
    for li in range(CFG.n_layers):
        carry_k = jnp.zeros((CFG.n_kv_heads, bucket, CFG.d_head))
        carry_v = jnp.zeros_like(carry_k)
        win = jnp.zeros((CFG.n_heads, CFG.window, bucket))
        acc = jnp.zeros((CFG.n_heads, bucket))
        vnorm = jnp.zeros((CFG.n_kv_heads, bucket))
        x_next = x
        start = 0
        while start < n:
            clen = min(chunk, n - start)
            rows = x[start : start + cbucket]
            if rows.shape[0] < cbucket:
                rows = jnp.concatenate(
                    [rows,
                     jnp.zeros((cbucket - rows.shape[0], CFG.d_model))]
                )
            meta = jnp.array([start, clen, n], jnp.int32)
            xo, k, v, winp, accp, vnp = M.layer_prefill_chunked(
                rows, carry_k, carry_v, meta, *lw_args(params, li)
            )
            x_next = x_next.at[start : start + clen].set(xo[:clen])
            carry_k = carry_k.at[:, start : start + clen].set(k[:, :clen])
            carry_v = carry_v.at[:, start : start + clen].set(v[:, :clen])
            win = win + winp
            acc = acc + accp
            vnorm = vnorm + vnp
            start += clen
        outs.append(dict(k=carry_k, v=carry_v, win_attn=win,
                         acc_attn=acc, vnorm=vnorm, x=x_next))
        x = x_next
    return outs


@pytest.mark.parametrize(
    "chunk,cbucket",
    [(64, 64), (48, 64), (17, 32), (100, 128)],
    ids=["aligned", "misaligned", "tiny", "single-chunk"],
)
def test_chunked_prefill_matches_monolithic(params, ids, chunk, cbucket):
    """Accumulated chunked prefill == the monolithic entrypoint, per layer.

    This is the lowering-side half of the rust bit-identity contract: the
    carry-in K/V + additive panel accumulation must reproduce the exact
    quantities layer_prefill emits (summation order differs from the pallas
    kernels' block order, hence float tolerances rather than equality)."""
    n = int(ids.shape[0])
    bucket = 128
    mono = run_prefill_padded(params, ids, bucket)
    got = run_prefill_chunked(params, ids, bucket, chunk, cbucket)
    for li in range(CFG.n_layers):
        np.testing.assert_allclose(
            got[li]["k"][:, :n], mono[li]["k"][:, :n], atol=3e-5, rtol=1e-4
        )
        np.testing.assert_allclose(
            got[li]["v"][:, :n], mono[li]["v"][:, :n], atol=3e-5, rtol=1e-4
        )
        np.testing.assert_allclose(
            got[li]["x"][:n], mono[li]["x"][:n], atol=3e-4, rtol=1e-3
        )
        np.testing.assert_allclose(
            got[li]["win_attn"], mono[li]["win_attn"], atol=3e-5
        )
        np.testing.assert_allclose(
            got[li]["acc_attn"][:, :n], mono[li]["acc_attn"][:, :n], atol=3e-4
        )
        np.testing.assert_allclose(
            got[li]["vnorm"][:, :n], mono[li]["vnorm"][:, :n],
            atol=3e-5, rtol=1e-4
        )
        # chunk-padding rows/columns must stay inert: the carry columns the
        # prompt never reached, and every non-owned window row, are zero
        assert float(jnp.abs(got[li]["win_attn"][:, :, n:]).max()) == 0.0
        assert float(jnp.abs(got[li]["k"][:, n:]).max()) == 0.0
        assert float(jnp.abs(got[li]["vnorm"][:, n:]).max()) == 0.0


def test_evict_chunked_full_carry_matches_monolithic(params, ids):
    """layer_prefill_chunked_evict with an identity carry (nothing evicted)
    reproduces the monolithic entrypoint: K/V rows, residual stream, additive
    panels, and every final-observation-window row."""
    n = int(ids.shape[0])
    cap = 128
    w = CFG.window
    mono = run_prefill_padded(params, ids, cap)
    for chunk, cbucket in [(48, 64), (17, 32)]:
        padded = jnp.concatenate(
            [ids, jnp.full((cap - n,), CFG.pad_id, jnp.int32)]
        )
        x = M.embed(padded, params["tok_emb"])
        for li in range(CFG.n_layers):
            carry_k = jnp.zeros((CFG.n_kv_heads, cap, CFG.d_head))
            carry_v = jnp.zeros_like(carry_k)
            acc = np.zeros((CFG.n_heads, cap), np.float32)
            vnorm = np.zeros((CFG.n_kv_heads, cap), np.float32)
            rows_abs = {}
            x_next = x
            start = 0
            while start < n:
                clen = min(chunk, n - start)
                rows = x[start:start + cbucket]
                if rows.shape[0] < cbucket:
                    rows = jnp.concatenate(
                        [rows,
                         jnp.zeros((cbucket - rows.shape[0], CFG.d_model))]
                    )
                carry_pos = np.full((cap,), -1, np.int32)
                carry_pos[:start] = np.arange(start)
                meta = jnp.array([start, clen, n, start], jnp.int32)
                xo, k, v, winp, accp, vnp = M.layer_prefill_chunked_evict(
                    rows, carry_k, carry_v, jnp.array(carry_pos), meta,
                    *lw_args(params, li)
                )
                x_next = x_next.at[start:start + clen].set(xo[:clen])
                carry_k = carry_k.at[:, start:start + clen].set(k[:, :clen])
                carry_v = carry_v.at[:, start:start + clen].set(v[:, :clen])
                accp, vnp, winp = map(np.asarray, (accp, vnp, winp))
                # identity compaction: carry column j is absolute position j
                acc += accp[:, :cap]
                acc[:, start:start + clen] += accp[:, cap:cap + clen]
                vnorm += vnp[:, :cap]
                vnorm[:, start:start + clen] += vnp[:, cap:cap + clen]
                for r in range(w):
                    wpos = start + clen - w + r
                    if wpos < start:
                        continue
                    row = winp[:, r, :cap].copy()
                    row[:, start:start + clen] += winp[:, r, cap:cap + clen]
                    assert wpos not in rows_abs, "window row owned once"
                    rows_abs[wpos] = row
                start += clen
            np.testing.assert_allclose(
                carry_k[:, :n], mono[li]["k"][:, :n], atol=3e-5, rtol=1e-4
            )
            np.testing.assert_allclose(
                carry_v[:, :n], mono[li]["v"][:, :n], atol=3e-5, rtol=1e-4
            )
            np.testing.assert_allclose(
                x_next[:n], mono[li]["x"][:n], atol=3e-4, rtol=1e-3
            )
            np.testing.assert_allclose(
                acc[:, :n], mono[li]["acc_attn"][:, :n], atol=3e-4
            )
            np.testing.assert_allclose(
                vnorm[:, :n], mono[li]["vnorm"][:, :n], atol=3e-5, rtol=1e-4
            )
            mono_win = np.asarray(mono[li]["win_attn"])
            for r in range(w):
                qpos = n - w + r
                np.testing.assert_allclose(
                    rows_abs[qpos][:, :n], mono_win[:, r, :n], atol=3e-5
                )
            x = x_next


def test_evict_chunked_compacted_carry_renormalizes(params, ids):
    """Dropping carry columns == renormalizing attention over the survivors
    (the masking contract streaming eviction relies on); dead columns and
    not-yet-seen chunk columns contribute exactly zero."""
    n = int(ids.shape[0])
    cap, cbucket, li, w = 64, 32, 1, CFG.window
    mono = run_prefill_padded(params, ids, 128)
    x_in = mono[li - 1]["x"]
    start, clen = n - 17, 17
    keep = np.arange(0, start, 2)
    carry_k = jnp.zeros((CFG.n_kv_heads, cap, CFG.d_head))
    carry_v = jnp.zeros_like(carry_k)
    carry_k = carry_k.at[:, :len(keep)].set(mono[li]["k"][:, keep])
    carry_v = carry_v.at[:, :len(keep)].set(mono[li]["v"][:, keep])
    carry_pos = np.full((cap,), -1, np.int32)
    carry_pos[:len(keep)] = keep
    rows = x_in[start:start + cbucket]
    meta = jnp.array([start, clen, n, len(keep)], jnp.int32)
    xo, k, v, winp, accp, vnp = M.layer_prefill_chunked_evict(
        rows, carry_k, carry_v, jnp.array(carry_pos), meta,
        *lw_args(params, li)
    )
    np.testing.assert_allclose(
        k[:, :clen], mono[li]["k"][:, start:n], atol=3e-5, rtol=1e-4
    )
    winp = np.asarray(winp)
    mono_win = np.asarray(mono[li]["win_attn"])
    for r in range(w):
        qpos = start + 1 + r  # == n - w + r
        for hh in range(CFG.n_heads):
            live_pos = np.concatenate([keep, np.arange(start, qpos + 1)])
            ref = mono_win[hh, r, live_pos]
            ref = ref / ref.sum()
            got = np.concatenate(
                [winp[hh, r, :len(keep)],
                 winp[hh, r, cap:cap + (qpos - start + 1)]]
            )
            np.testing.assert_allclose(got, ref, atol=5e-4, rtol=1e-3)
        # dead carry columns and future chunk columns are exactly zero
        assert float(np.abs(winp[:, r, len(keep):cap]).max()) == 0.0
        assert float(
            np.abs(winp[:, r, cap + (qpos - start + 1):]).max()
        ) == 0.0
    # accumulated mass / value norms only land on live columns
    accp, vnp = np.asarray(accp), np.asarray(vnp)
    assert float(np.abs(accp[:, len(keep):cap]).max()) == 0.0
    assert float(np.abs(vnp[:, :cap]).max()) == 0.0
    assert float(np.abs(vnp[:, cap + clen:]).max()) == 0.0


def test_logits_match_reference(params, ids):
    n = int(ids.shape[0])
    outs = run_prefill_padded(params, ids, 128)
    x_last = outs[-1]["x"][n - 1 : n]
    got = M.logits(x_last, params["ln_f"], params["unembed"])
    _, want = M.reference_prefill(params, ids)
    np.testing.assert_allclose(got, want, atol=3e-4, rtol=1e-3)


def test_decode_step_matches_prefill(params):
    """Prefill N tokens, decode token N+1 -> same hidden state as prefilling
    all N+1 tokens. This is the contract the decode loop depends on."""
    rng = np.random.default_rng(1)
    n = 64
    all_ids = jnp.array(rng.integers(0, 256, size=n + 1), jnp.int32)
    ref_layers, _ = M.reference_prefill(params, all_ids)

    prefix_layers, _ = M.reference_prefill(params, all_ids[:n])

    m = 128  # decode bucket
    x = M.embed(all_ids[n : n + 1], params["tok_emb"])
    pos = jnp.array([n], jnp.int32)
    for li in range(CFG.n_layers):
        k_cache = jnp.zeros((CFG.n_kv_heads, m, CFG.d_head))
        v_cache = jnp.zeros_like(k_cache)
        valid = jnp.zeros((CFG.n_kv_heads, m))
        k_cache = k_cache.at[:, :n].set(prefix_layers[li]["k"])
        v_cache = v_cache.at[:, :n].set(prefix_layers[li]["v"])
        valid = valid.at[:, :n].set(1.0)
        x, k_new, v_new, attn = M.layer_decode(
            x, k_cache, v_cache, valid, pos, *lw_args(params, li)
        )
        np.testing.assert_allclose(
            k_new, ref_layers[li]["k"][:, n], atol=1e-4, rtol=1e-3
        )
        np.testing.assert_allclose(
            x[0], ref_layers[li]["x_out"][n], atol=1e-3, rtol=1e-2
        )
        # attention row must be a distribution over the n+1 live slots
        np.testing.assert_allclose(jnp.sum(attn, axis=-1), jnp.ones(CFG.n_heads),
                                   rtol=1e-5)
        assert float(jnp.abs(attn[:, n:m]).max()) == 0.0


def test_layer_decode_batched_matches_serial(params):
    """The batched decode entrypoint == looping layer_decode per session."""
    rng = np.random.default_rng(3)
    b, n, m = 3, 40, 64
    li = 1
    xs, ks, vs, valids, poss = [], [], [], [], []
    for s in range(b):
        ids = jnp.array(rng.integers(0, 256, size=n + s), jnp.int32)
        layers, _ = M.reference_prefill(params, ids)
        k_cache = jnp.zeros((CFG.n_kv_heads, m, CFG.d_head))
        v_cache = jnp.zeros_like(k_cache)
        valid = np.zeros((CFG.n_kv_heads, m), np.float32)
        ln = n + s
        k_cache = k_cache.at[:, :ln].set(layers[li]["k"])
        v_cache = v_cache.at[:, :ln].set(layers[li]["v"])
        valid[:, :ln] = 1.0
        xs.append(M.embed(ids[-1:], params["tok_emb"])[0])
        ks.append(k_cache)
        vs.append(v_cache)
        valids.append(jnp.array(valid))
        poss.append(ln)

    bx = jnp.stack(xs)
    bk = jnp.stack(ks)
    bv = jnp.stack(vs)
    bvalid = jnp.stack(valids)
    bpos = jnp.array(poss, jnp.int32)
    x_out, k_new, v_new, attn = M.layer_decode_batched(
        bx, bk, bv, bvalid, bpos, *lw_args(params, li)
    )
    assert x_out.shape == (b, CFG.d_model)
    assert k_new.shape == (b, CFG.n_kv_heads, CFG.d_head)
    assert attn.shape == (b, CFG.n_heads, m + 1)
    for s in range(b):
        ref = M.layer_decode(
            bx[s][None, :], bk[s], bv[s], bvalid[s],
            jnp.array([poss[s]], jnp.int32), *lw_args(params, li)
        )
        np.testing.assert_allclose(x_out[s], ref[0][0], atol=1e-6)
        np.testing.assert_allclose(k_new[s], ref[1], atol=1e-6)
        np.testing.assert_allclose(v_new[s], ref[2], atol=1e-6)
        np.testing.assert_allclose(attn[s], ref[3], atol=1e-6)


def test_decode_eviction_mask_equals_compaction(params):
    """Masking out slots == physically removing them (scatter vs compact)."""
    rng = np.random.default_rng(5)
    n, m = 48, 64
    ids = jnp.array(rng.integers(0, 256, size=n), jnp.int32)
    layers, _ = M.reference_prefill(params, ids)
    li = 1
    keep = np.sort(rng.choice(n, size=20, replace=False))

    x = M.embed(ids[-1:], params["tok_emb"])  # arbitrary decode input
    pos = jnp.array([n], jnp.int32)

    # (a) masked layout: full cache, valid=keep mask
    k_cache = jnp.zeros((CFG.n_kv_heads, m, CFG.d_head))
    v_cache = jnp.zeros_like(k_cache)
    valid = np.zeros((CFG.n_kv_heads, m), np.float32)
    k_cache = k_cache.at[:, :n].set(layers[li]["k"])
    v_cache = v_cache.at[:, :n].set(layers[li]["v"])
    valid[:, keep] = 1.0
    out_a = M.layer_decode(x, k_cache, v_cache, jnp.array(valid), pos,
                           *lw_args(params, li))

    # (b) compacted layout: only kept slots, packed to the front
    k2 = jnp.zeros((CFG.n_kv_heads, m, CFG.d_head))
    v2 = jnp.zeros_like(k2)
    valid2 = np.zeros((CFG.n_kv_heads, m), np.float32)
    k2 = k2.at[:, : len(keep)].set(layers[li]["k"][:, keep])
    v2 = v2.at[:, : len(keep)].set(layers[li]["v"][:, keep])
    valid2[:, : len(keep)] = 1.0
    out_b = M.layer_decode(x, k2, v2, jnp.array(valid2), pos,
                           *lw_args(params, li))

    np.testing.assert_allclose(out_a[0], out_b[0], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(out_a[1], out_b[1], atol=1e-6)


def test_ragged_head_lengths(params):
    """Different per-kv-head valid counts (AdaKV layouts) are honoured."""
    rng = np.random.default_rng(9)
    n, m = 40, 64
    ids = jnp.array(rng.integers(0, 256, size=n), jnp.int32)
    layers, _ = M.reference_prefill(params, ids)
    li = 0
    x = M.embed(ids[-1:], params["tok_emb"])
    pos = jnp.array([n], jnp.int32)
    k_cache = jnp.zeros((CFG.n_kv_heads, m, CFG.d_head))
    v_cache = jnp.zeros_like(k_cache)
    valid = np.zeros((CFG.n_kv_heads, m), np.float32)
    lens = [10, 20, 30, 40]
    for hh, ln in enumerate(lens):
        valid[hh, :ln] = 1.0
    k_cache = k_cache.at[:, :n].set(layers[li]["k"])
    v_cache = v_cache.at[:, :n].set(layers[li]["v"])
    _, _, _, attn = M.layer_decode(x, k_cache, v_cache, jnp.array(valid), pos,
                                   *lw_args(params, li))
    g = CFG.group_size
    for hh, ln in enumerate(lens):
        for member in range(g):
            row = attn[hh * g + member]
            assert float(jnp.abs(row[ln:m]).max()) == 0.0
            np.testing.assert_allclose(float(jnp.sum(row)), 1.0, rtol=1e-5)


def test_rope_relative_phase():
    """RoPE inner products depend only on relative offsets."""
    rng = np.random.default_rng(2)
    x = jnp.array(rng.normal(size=(1, 1, CFG.d_head)), jnp.float32)
    y = jnp.array(rng.normal(size=(1, 1, CFG.d_head)), jnp.float32)

    def dot_at(px, py):
        xr = M.rope(x, jnp.array([px], jnp.int32))
        yr = M.rope(y, jnp.array([py], jnp.int32))
        return float(jnp.sum(xr * yr))

    np.testing.assert_allclose(dot_at(3, 7), dot_at(103, 107), rtol=1e-4)
    np.testing.assert_allclose(dot_at(0, 50), dot_at(20, 70), rtol=1e-4)


def test_embed_lookup(params):
    ids = jnp.array([0, 5, 255, CFG.pad_id], jnp.int32)
    x = M.embed(ids, params["tok_emb"])
    np.testing.assert_allclose(x[1], params["tok_emb"][5])
